"""Benchmarks reproducing the paper's tables/figures from the calibrated
cost model + the functional PIM engine.

  fig7    — PEP cycle counts (operand dims annotated), paper Fig. 7
  fig8    — AME instruction cycles / FLOP-per-cycle / GFLOP/s, paper Fig. 8
  fig9    — mfmacc FLOP/cycle vs tile size scaling, paper Fig. 9
  table3  — comparison row vs MPC-Wrapper / RNN-T, paper Table 3
  channels— device-runtime multi-pseudo-channel scaling sweep (makespan
            semantics; the paper's named future work, via repro.runtime)
  residency— device-resident operands: steady-state decode h2d drops to
            activations-only, bit-exact with the fresh-transfer path, and
            the serve-loop decode offload roofline (dumps the
            ``results/dryrun/*.pim_offload.json`` BENCH artifact)
  engine  — fast-path microbench: batched vs per-tile numeric executors
            (bit-exact) and closed-form vs generator-walk analytic costs
            (identical ledgers), with wall-clock regression gates; the
            measured numbers feed ``results/BENCH_runtime.json``
  cluster — multi-stack scaling sweep: fixed-total-channel reshapes are
            makespan-parity (host-link bytes only where shards cross
            stacks), 1/2/4-stack GEMM + balanced-GEMV scaling efficiency,
            and the multi-stack decode offload; scaling-efficiency gates
            feed ``results/BENCH_runtime.json`` (CI ``bench-cluster``)
  decode  — async dependency-aware decode scheduling: intra-layer
            q/k/v + gate/up overlap on disjoint channel groups
            (serialized-vs-async step makespan) and the 4-request
            cross-stack layer pipeline; overlap >= 1.3x and pipeline
            efficiency >= 0.75 gates feed ``results/BENCH_runtime.json``
            (CI ``bench-decode``)
  obs     — observability layer: Chrome-trace export of an async decode
            step (track/flow structure validated, artifact at
            ``results/obs_profile.json`` for Perfetto), critical-path
            attribution (coverage == makespan gate, exact), and the
            metrics-registry overhead gate (< 5% on instrumented async
            decode steps); gates feed ``results/BENCH_runtime.json``
            (CI ``bench-obs``)
  faults  — fault injection + graceful degradation: 1-dead-channel-of-16
            degradation curve (<= 16/15 x 1.05 of the ideal makespan),
            empty-FaultPlan overhead (< 5%, ledgers/traces exactly
            equal), and flaky-link seed determinism; gates feed
            ``results/BENCH_runtime.json`` (CI ``bench-faults``)
  kv      — KV-cache-resident attention decode: paged-resident vs
            streamed attention step at 8k context (>= 4x), steady-state
            per-step h2d flat in context length (new-token bytes only),
            and paged-eviction seed determinism; gates feed
            ``results/BENCH_runtime.json`` (CI ``bench-kv``)
  serve   — production-traffic serving under load: the throughput-vs-
            SLO-attainment frontier (6 Poisson load points per model,
            qwen3-1.7b + mixtral-8x22b) for disaggregated
            prefill/decode vs the colocated baseline (>= 1.3x goodput
            at the SLO knee), seed-identical latency percentiles across
            two runs, and zero-traffic additivity (ledgers ==-equal,
            traces byte-identical with the traffic layer off); the
            frontier + gates feed ``results/BENCH_runtime.json``
            (CI ``bench-serve``)

Each returns rows of (name, us_per_call, derived) where us_per_call is the
measured host execution time of the functional engine (small tiles; the
cycle numbers themselves are the calibrated model) and ``derived`` carries
the paper-comparable quantity.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import cost as cost_mod
from repro.core.engine import AMEEngine
from repro.core.isa import PIM_FREQ_HZ, THEORETICAL_PEAK_FLOP_PER_CYCLE
from repro.runtime import PIMRuntime, pim_gemm, pim_gemv

Row = Tuple[str, float, str]

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def _time_engine(fn, reps=3) -> float:
    fn()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def fig7_pep_cycles() -> List[Row]:
    rows = []
    rng = np.random.default_rng(0)

    def run_ew(kind):
        eng = AMEEngine()
        a = jnp.asarray(rng.standard_normal((128, 64)), jnp.float16)
        eng.msettilek(64)
        eng.mld(0, a), eng.mld(1, a)
        getattr(eng, f"mf{kind}")(0, 0, 1)

    for kind in ("add", "mul", "sub"):
        rep = cost_mod.elementwise_cost(kind, 128, 2048)
        us = _time_engine(lambda k=kind: run_ew(k))
        rows.append((f"fig7/{kind}-pep_128x2048", us,
                     f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    rep = cost_mod.mfmacc_cost(128, 2048, 1)
    us = _time_engine(lambda: pim_gemv(
        jnp.asarray(rng.standard_normal((128, 256)), jnp.float16),
        jnp.asarray(rng.standard_normal((256,)), jnp.float16)))
    rows.append(("fig7/mac-pep_128x2048x1", us,
                 f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    rep = cost_mod.mfmacc_cost(128, 8, 256)
    rows.append(("fig7/mac-pep_128x8x256", us,
                 f"cycles={rep.cycles:.0f} cmds={rep.commands}"))
    return rows


def fig8_ame_instructions() -> List[Row]:
    rows = []
    for name, rep in [
        ("mfadd.h_128x4096", cost_mod.elementwise_cost("add", 128, 4096)),
        ("mfmul.h_128x4096", cost_mod.elementwise_cost("mul", 128, 4096)),
        ("mfsub.h_128x4096", cost_mod.elementwise_cost("sub", 128, 4096)),
        ("mfmacc.h_128x4096", cost_mod.mfmacc_cost(128, 4096, 128)),
    ]:
        rows.append((f"fig8/{name}", 0.0,
                     f"cycles={rep.cycles:.0f} flop/cyc={rep.flop_per_cycle:.2f} "
                     f"gflops={rep.gflops:.2f} launches={rep.launches}"))
    sat = cost_mod.saturated_flop_per_cycle("mac")
    rows.append(("fig8/mfmacc_saturated", 0.0,
                 f"flop/cyc={sat:.2f} paper=59.4 "
                 f"gflops={sat * PIM_FREQ_HZ / 1e9:.2f} paper_gflops=14.9"))
    # paper reproduction gates
    assert abs(sat - 59.4) < 0.1, sat
    assert abs(sat * PIM_FREQ_HZ / 1e9 - 14.9) < 0.1
    assert cost_mod.mfmacc_cost(128, 4096, 128).launches == 256
    assert sat <= THEORETICAL_PEAK_FLOP_PER_CYCLE / 2
    return rows


def fig9_tile_scaling() -> List[Row]:
    rows = []
    for k in (8, 16, 64, 128, 256, 512, 1024, 2048):
        rep = cost_mod.mfmacc_cost(128, k, 1)
        rows.append((f"fig9/mfmacc_128x{k}x1", 0.0,
                     f"flop/cyc={rep.flop_per_cycle:.2f}"))
    r88 = cost_mod.mfmacc_cost(128, 8, 256)   # (*) same perf as 128x2048x1
    rows.append(("fig9/mfmacc_128x8x256", 0.0,
                 f"flop/cyc={r88.flop_per_cycle:.2f}"))
    return rows


def table3_comparison() -> List[Row]:
    ours = cost_mod.saturated_flop_per_cycle("mac")
    rows = [
        ("table3/this-work", 0.0,
         f"pchannels=1 inmem_acc=yes elementwise=yes gemv+gemm=yes "
         f"flop/cyc={ours:.1f}"),
        ("table3/mpc-wrapper", 0.0,
         "pchannels=16 inmem_acc=no elementwise=no gemv_only=yes "
         "flop/cyc=58.1"),
        ("table3/rnn-t", 0.0,
         "pchannels=1 inmem_acc=no gemv_only=yes flop/cyc=n.a."),
        ("table3/multichannel-16", 0.0,
         f"pchannels=16 aggregate_gflops="
         f"{16 * ours * PIM_FREQ_HZ / 1e9:.1f} "
         "(upper bound; see `channels` sweep for makespan-based scaling)"),
    ]
    assert ours > 58.1  # the paper's headline comparison
    return rows


def channel_sweep() -> List[Row]:
    """Multi-pseudo-channel scaling through the device runtime (analytic
    cost mode — same ledgers as numeric execution, property-tested).

    Reports makespan-based speedup and per-channel utilization for the
    paper-scale GEMM (512x4096x512, 2d-block placement: at 16 channels
    every channel executes exactly the paper's 128x4096x128 max tile) and
    a skinny GEMV where AMD-style balanced placement must beat naive row
    striping to scale at all.
    """
    rows = []
    # paper reproduction gate: the single-channel engine underneath the
    # runtime still hits the 59.4 FLOP/cycle headline at max tile
    sat = cost_mod.saturated_flop_per_cycle("mac")
    assert abs(sat - 59.4) < 0.1, sat
    head = cost_mod.max_tile_mfmacc()
    rows.append(("channels/maxtile_mfmacc_1ch", 0.0,
                 f"flop/cyc={head.flop_per_cycle:.1f} "
                 f"saturated={sat:.1f} paper=59.4"))

    def sweep(tag, m, k, n, placement):
        a = np.zeros((m, k), np.float16)      # analytic mode: shapes only
        b = np.zeros((k, n), np.float16)
        base = None
        out = []
        for ch in (1, 2, 4, 8, 16):
            _, rep = pim_gemm(a, b, channels=ch, placement=placement,
                              execute=False)
            base = base or rep.makespan_cycles
            us = rep.utilizations()
            busy = sum(1 for c in rep.per_channel if c.busy_cycles > 0)
            out.append((f"channels/{tag}_{placement}_{ch}ch", 0.0,
                        f"makespan={rep.makespan_cycles:.0f} "
                        f"speedup={base / rep.makespan_cycles:.2f} "
                        f"gflops={rep.gflops:.1f} busy={busy} "
                        f"util_mean={sum(us) / len(us):.2f} "
                        f"util_min={min(us):.2f}"))
        return out, base / rep.makespan_cycles, rep.makespan_cycles

    gemm_rows, gemm_speedup, _ = sweep("gemm_512x4096x512",
                                       512, 4096, 512, "2d-block")
    rows += gemm_rows
    rs_rows, _, rs_makespan = sweep("gemv_256x8192", 256, 8192, 1,
                                    "row-striped")
    rows += rs_rows
    bal_rows, bal_speedup, bal_makespan = sweep("gemv_256x8192",
                                                256, 8192, 1, "balanced")
    rows += bal_rows

    # scaling gates: GEMM scales near-linearly in makespan; balanced
    # placement beats row striping on the skinny GEMV (AMD's result)
    assert gemm_speedup > 10, gemm_speedup
    assert bal_makespan < rs_makespan, (bal_makespan, rs_makespan)
    rows.append(("channels/gemv_balanced_vs_striped_16ch", 0.0,
                 f"balanced_makespan={bal_makespan:.0f} "
                 f"striped_makespan={rs_makespan:.0f} "
                 f"advantage={rs_makespan / bal_makespan:.2f}x"))

    # paper-scale shapes, practical only through the closed-form analytic
    # path (O(1) per shard; the generator walk is O(#tiles) ~ 64k tiles
    # for the 8192^3 GEMM and the full-vocab lm-head GEMV).  Operands are
    # 0-strided views — analytic mode never reads values, and a real
    # (151936, 8192) fp16 buffer would be 2.5 GB
    for tag, (pm, pk, pn), placement in [
            ("gemm_8192x8192x8192", (8192, 8192, 8192), "2d-block"),
            ("gemv_151936x8192", (151936, 8192, 1), "balanced")]:
        t0 = time.perf_counter()
        _, rep = pim_gemm(np.broadcast_to(np.float16(0), (pm, pk)),
                          np.broadcast_to(np.float16(0), (pk, pn)),
                          channels=16, placement=placement, execute=False)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"channels/paper_scale_{tag}_16ch", us,
                     f"makespan={rep.makespan_cycles:.0f} "
                     f"gflops={rep.gflops:.1f} "
                     f"util_mean={sum(rep.utilizations()) / 16:.2f}"))
    return rows


def residency_sweep() -> List[Row]:
    """Device-resident operands (the serve-loop decode regime).

    Steady-state gate: with weights placed once (``PIMRuntime.place``),
    every decode GEMV's h2d traffic is the activation vector alone — the
    weight re-transfer of the fresh path shows up entirely as resident
    reuse, and outputs stay bit-exact with fresh transfers at 1, 4 and 16
    channels.  Also accounts the GEMM->elementwise epilogue fusion and
    dumps the serve decode-offload roofline artifact.
    """
    rows = []
    rng = np.random.default_rng(3)
    m, k, steps = 256, 2048, 3

    def rand(*shape):
        return (rng.standard_normal(shape) * 0.1).astype(np.float16)

    a = rand(m, k)
    xs = [rand(k) for _ in range(steps)]
    for ch in (1, 4, 16):
        rt_fresh, rt_res = PIMRuntime(channels=ch), PIMRuntime(channels=ch)
        w = rt_res.place(a, placement="balanced")
        weight_upload = sum(d.xfer.h2d_bytes for d in rt_res.stack)
        fresh_h2d = res_h2d = res_reuse = 0
        for t in range(steps):
            y_f, rep_f = rt_fresh.gemv(a, xs[t], placement="balanced")
            y_r, rep_r = rt_res.gemv(w, xs[t], placement="balanced")
            # acceptance: resident path bit-exact with fresh transfers
            assert np.array_equal(np.asarray(y_f), np.asarray(y_r)), ch
            # acceptance: resident h2d = activations only — the h2d the
            # fresh path ships on top is exactly the residency reuse, and
            # within-op x-slice dedupe is identical on both paths
            assert rep_f.total_h2d_bytes - rep_r.total_h2d_bytes \
                == rep_r.total_reuse_bytes, ch
            assert rep_r.total_dedupe_bytes == rep_f.total_dedupe_bytes, ch
            assert rep_f.total_reuse_bytes == 0, ch
            assert rep_r.total_d2h_bytes == rep_f.total_d2h_bytes, ch
            if t > 0:      # steady state: no weight re-transfer at all
                assert rep_r.total_h2d_bytes == res_h2d, ch
            fresh_h2d, res_h2d = rep_f.total_h2d_bytes, rep_r.total_h2d_bytes
            res_reuse = rep_r.total_reuse_bytes
        assert res_h2d < fresh_h2d
        rows.append((f"residency/gemv_{m}x{k}_{ch}ch", 0.0,
                     f"fresh_h2d={fresh_h2d} resident_h2d={res_h2d} "
                     f"reuse={res_reuse} upload_once={weight_upload} "
                     f"h2d_cut={fresh_h2d / res_h2d:.1f}x bit_exact=yes"))

    # GEMM -> elementwise epilogue: intermediate never round-trips
    rt = PIMRuntime(channels=4)
    b, c = rand(k, 64), rand(m, 64)
    h, rep_g = rt.gemm(a, b, placement="row-striped", keep_output=True)
    _, rep_e = rt.elementwise("add", h, c, placement="row-striped")
    assert rep_g.total_d2h_bytes == 0          # output stayed resident
    assert rep_e.total_h2d_bytes == c.size * 2  # only the epilogue operand
    rows.append(("residency/gemm_ew_epilogue_4ch", 0.0,
                 f"gemm_d2h={rep_g.total_d2h_bytes} "
                 f"ew_h2d={rep_e.total_h2d_bytes} "
                 f"ew_reuse={rep_e.total_reuse_bytes} fused=yes"))

    # serve-loop decode offload roofline (analytic, reduced config) + the
    # BENCH artifact for future cost-model regressions
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()
    off = DecodeOffload(cfg, channels=16, placement="balanced")
    for _ in range(steps):
        rec = off.step(4)
    assert rec.reuse_bytes == off.weight_bytes      # weights fully amortized
    assert all(s.h2d_bytes == rec.h2d_bytes for s in off.steps)
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{cfg.name}.decode.pim_offload.json"
    roof = off.dump(str(out))
    rows.append((f"residency/serve_offload_{cfg.name}_16ch", 0.0,
                 f"steady_h2d={roof['steady_h2d_bytes']} "
                 f"weights={roof['weight_bytes']} "
                 f"pim_s={roof['steady_pim_s']:.2e} "
                 f"host_s={roof['steady_host_s']:.2e} "
                 f"host_bound={roof['steady_host_bound']} "
                 f"artifact={out.name}"))
    return rows


#: measured fast-path metrics of the last ``engine`` section run — read by
#: benchmarks.run when writing the ``results/BENCH_runtime.json`` artifact
LAST_ENGINE_METRICS: dict = {}

#: measured multi-stack metrics of the last ``cluster`` section run —
#: merged into ``results/BENCH_runtime.json`` the same way
LAST_CLUSTER_METRICS: dict = {}

#: measured async-scheduler metrics of the last ``decode`` section run —
#: merged into ``results/BENCH_runtime.json`` the same way (CI
#: ``bench-decode`` gates overlap speedup and pipeline efficiency)
LAST_DECODE_METRICS: dict = {}

#: measured observability metrics of the last ``obs`` section run —
#: merged into ``results/BENCH_runtime.json`` the same way (CI
#: ``bench-obs`` gates coverage == makespan and collection overhead)
LAST_OBS_METRICS: dict = {}

#: measured fault-injection metrics of the last ``faults`` section run —
#: merged into ``results/BENCH_runtime.json`` the same way (CI
#: ``bench-faults`` gates the degradation curve, empty-plan overhead,
#: and seed determinism)
LAST_FAULTS_METRICS: dict = {}

#: measured KV-cache metrics of the last ``kv`` section run — merged
#: into ``results/BENCH_runtime.json`` the same way (CI ``bench-kv``
#: gates the paged-vs-streamed attention speedup, context-independent
#: per-step h2d, and eviction determinism)
LAST_KV_METRICS: dict = {}

#: measured serving metrics of the last ``serve`` section run — merged
#: into ``results/BENCH_runtime.json`` *unrounded* (the ``frontier``
#: value is a nested per-config structure, not a scalar); CI
#: ``bench-serve`` gates the disagg-vs-colocated knee-goodput ratio,
#: seed determinism, and zero-traffic additivity
LAST_SERVE_METRICS: dict = {}

#: measured routed-MoE metrics of the last ``moe`` section run — merged
#: into ``results/BENCH_runtime.json`` the same way; CI ``bench-moe``
#: gates the skew+replication speedup over round-robin placement, the
#: max/mean stack-load balance, and seed determinism
LAST_MOE_METRICS: dict = {}


def cluster_sweep() -> List[Row]:
    """Multi-stack cluster scaling (analytic mode — ledgers identical to
    numeric execution, property-tested in tests/test_cluster.py).

    Gates (CI ``bench-cluster``):

    * fixed-total-channel parity — 16 flat channels reshaped as 1x16 /
      2x8 / 4x4 stacks produce *identical* makespans, with host-link
      bytes appearing only where shards actually cross stacks;
    * 1/2/4-stack scaling efficiency >= 0.9 for the paper-scale GEMM
      (2d-block) and the full-vocab decode GEMV (balanced) at 16
      channels per stack — cross-stack traffic rides the host link, so
      makespan scaling must stay near-linear;
    * the multi-stack decode offload amortizes weights (reuse == weight
      bytes) with per-step cycles identical to single-stack (stack-
      restricted ops keep the per-stack decomposition) and zero link
      traffic (layers live on their home stacks).
    """
    rows: List[Row] = []

    # fixed total channels: makespan parity, link bytes only on crossings
    m = k = n = 512
    a = np.broadcast_to(np.float16(0), (m, k))
    b = np.broadcast_to(np.float16(0), (k, n))
    parity = {}
    for stacks, cps in [(1, 16), (2, 8), (4, 4)]:
        _, rep = pim_gemm(a, b, channels=cps, placement="2d-block",
                          execute=False, stacks=stacks)
        parity[stacks] = rep.makespan_cycles
        rows.append((f"cluster/parity_gemm_{m}x{k}x{n}_{stacks}x{cps}", 0.0,
                     f"makespan={rep.makespan_cycles:.0f} "
                     f"link_bytes={rep.host_link_bytes} "
                     f"cluster_makespan={rep.cluster_makespan_cycles:.0f}"))
        if stacks == 1:
            assert rep.host_link_bytes == 0
        else:
            assert rep.host_link_bytes > 0     # 2d-block replicates boxes
    assert parity[2] == parity[1] and parity[4] == parity[1], parity
    LAST_CLUSTER_METRICS["parity_makespan"] = parity[1]

    # 1/2/4-stack scaling at 16 channels per stack
    def scale(tag, pm, pk, pn, placement):
        aa = np.broadcast_to(np.float16(0), (pm, pk))
        bb = np.broadcast_to(np.float16(0), (pk, pn))
        base = None
        eff = {}
        for stacks in (1, 2, 4):
            t0 = time.perf_counter()
            _, rep = pim_gemm(aa, bb, channels=16, placement=placement,
                              execute=False, stacks=stacks)
            us = (time.perf_counter() - t0) * 1e6
            base = base or rep.cluster_makespan_cycles
            speedup = base / rep.cluster_makespan_cycles
            eff[stacks] = speedup / stacks
            rows.append((f"cluster/{tag}_{placement}_{stacks}stack", us,
                         f"makespan={rep.makespan_cycles:.0f} "
                         f"speedup={speedup:.2f} eff={eff[stacks]:.2f} "
                         f"link_bytes={rep.host_link_bytes}"))
        return eff

    gemm_eff = scale("gemm_2048x4096x2048", 2048, 4096, 2048, "2d-block")
    gemv_eff = scale("gemv_151936x8192", 151936, 8192, 1, "balanced")
    assert gemm_eff[4] >= 0.9, gemm_eff
    assert gemv_eff[4] >= 0.9, gemv_eff
    LAST_CLUSTER_METRICS.update(
        gemm_eff_4stack=gemm_eff[4], gemv_eff_4stack=gemv_eff[4])

    # multi-stack decode offload: layers on home stacks
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()
    base_cycles = None
    for stacks in (1, 2, 4):
        off = DecodeOffload(cfg, channels=16, stacks=stacks,
                            placement="balanced")
        for _ in range(2):
            rec = off.step(4)
        assert rec.reuse_bytes == off.weight_bytes   # amortized
        base_cycles = base_cycles or rec.pim_cycles
        # stack-restricted ops keep the per-stack decomposition: the
        # serialized decode step costs the same cycles at any stack count
        assert rec.pim_cycles == base_cycles, (stacks, rec.pim_cycles)
        roof = off.roofline()
        assert roof["host_link_bytes"] == 0          # home-stack locality
        ups = roof["upload_bytes_per_stack"] or [off.upload_bytes]
        rows.append((f"cluster/decode_{cfg.name}_{stacks}stack", 0.0,
                     f"pim_s={rec.pim_s:.2e} h2d={rec.h2d_bytes} "
                     f"upload_per_stack={'/'.join(map(str, ups))} "
                     f"link_bytes={roof['host_link_bytes']}"))
    LAST_CLUSTER_METRICS["decode_step_cycles"] = base_cycles
    return rows


def decode_async_sweep() -> List[Row]:
    """Async dependency-aware decode scheduling (analytic mode).

    Gates (CI ``bench-decode``):

    * ``decode_overlap_speedup`` >= 1.3 — `DecodeOffload(stacks=4,
      async_mode=True)` submits each decode step as an op DAG (q/k/v
      and gate/up concurrent on disjoint channel groups of the home
      stack) and its steady-state step makespan must beat the
      serialized barrier-per-op step by >= 1.3x.  Decode-shaped matmuls
      are launch-floor dominated, so giving independent ops their own
      channels removes serialized per-op floors without inflating work;
    * ``pipeline_eff_4stack`` >= 0.75 — a 4-request pipelined decode
      batch (one chain per request, layer blocks wave-pipelining across
      the 4 home stacks) must keep per-stack efficiency
      ``T1 / T4 = (requests x single-chain makespan) / (stacks x
      pipelined makespan)`` at >= 0.75.

    The pipeline case uses an 8-layer variant of the reduced config (2
    layers per home stack) so the lm_head tail on the last stack is
    amortized over its layer block; the overlap case is the plain
    reduced config, measured at batch=1 (the per-request decode step).
    """
    rows: List[Row] = []
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()

    # intra-layer overlap: serialized vs async step makespan (steady
    # state: step 2 — step 1's start can ride the upload tail)
    sync = DecodeOffload(cfg, channels=16, stacks=4, placement="balanced")
    asy = DecodeOffload(cfg, channels=16, stacks=4, placement="balanced",
                        async_mode=True)
    sync.step(1), asy.step(1)
    rec_s, rec_a = sync.step(1), asy.step(1)
    overlap = rec_s.pim_cycles / rec_a.pim_cycles
    rows.append((f"decode/overlap_{cfg.name}_4stack", 0.0,
                 f"serial={rec_s.pim_cycles:.0f} "
                 f"async={rec_a.pim_cycles:.0f} speedup={overlap:.2f} "
                 f"reuse_ok={rec_a.reuse_bytes == asy.weight_bytes}"))
    assert rec_a.reuse_bytes == asy.weight_bytes    # weights amortized
    assert overlap >= 1.3, overlap

    # per-group overlap detail for the docs table: serialized sum vs
    # concurrent-group cost of one layer's independent matmul sets
    t_probe = time.perf_counter()
    from repro.serve.offload import _group_split, _probe_cycles
    d, hd = cfg.d_model, cfg.head_dim_
    groups = {
        "qkv": [(cfg.n_heads * hd, d), (cfg.n_kv_heads * hd, d),
                (cfg.n_kv_heads * hd, d)],
        "gate_up": [(cfg.d_ff, d), (cfg.d_ff, d)],
    }
    for tag, shapes in groups.items():
        serial = sum(_probe_cycles(m, k, 16, "balanced")
                     for m, k in shapes)
        split = _group_split(tuple(shapes), 16, "balanced")
        conc = max(_probe_cycles(m, k, c, "balanced")
                   for (m, k), c in zip(shapes, split))
        rows.append((f"decode/group_{tag}", 0.0,
                     f"serial={serial:.0f} concurrent={conc:.0f} "
                     f"split={'/'.join(map(str, split))} "
                     f"overlap={serial / conc:.2f}x"))
    probe_us = (time.perf_counter() - t_probe) * 1e6

    # multi-request pipeline: 4 chains over 4 home stacks, 8 steps;
    # 8 layers = 2 per stack so the lm_head tail amortizes
    cfg8 = cfg.replace(n_layers=8)
    t0 = time.perf_counter()
    p1 = DecodeOffload(cfg8, channels=16, stacks=4, placement="balanced",
                       async_mode=True).pipeline(1, 8)
    p4 = DecodeOffload(cfg8, channels=16, stacks=4, placement="balanced",
                       async_mode=True).pipeline(4, 8)
    us = (time.perf_counter() - t0) * 1e6
    eff = p1["makespan_cycles"] / p4["makespan_cycles"]
    busy = p4["per_stack_busy_cycles"]
    rows.append((f"decode/pipeline_{cfg8.name}_4x8steps", us,
                 f"T1={p1['makespan_cycles']:.0f} "
                 f"T4={p4['makespan_cycles']:.0f} eff={eff:.2f} "
                 f"stack_busy_max={max(busy):.0f}"))
    assert eff >= 0.75, eff
    # conservation: pipelining 4x the chains costs exactly 4x the busy
    assert abs(sum(busy) - 4 * sum(p1["per_stack_busy_cycles"])) < 1e-6
    rows.append(("decode/probe_split_search", probe_us,
                 "memoized channel-group split oracle"))
    LAST_DECODE_METRICS.update(
        decode_overlap_speedup=overlap,
        serial_step_cycles=rec_s.pim_cycles,
        async_step_cycles=rec_a.pim_cycles,
        pipeline_eff_4stack=eff,
        pipeline_t1_cycles=p1["makespan_cycles"],
        pipeline_t4_cycles=p4["makespan_cycles"])
    return rows


def obs_sweep() -> List[Row]:
    """Observability gates (CI ``bench-obs``).

    * **Chrome-trace export** — an async 2-stack ``DecodeOffload`` step's
      timeline serializes to valid Chrome Trace Event JSON
      (``results/obs_profile.json``, loadable at ui.perfetto.dev): one
      op track per busy (stack, channel), a host-link track, and dep
      flow arrows with matched ``s``/``f`` pairs;
    * **critical-path coverage == makespan** — the backward walk's
      segments partition ``[0, timeline.now]`` exactly (clock values
      propagate bit-exactly, so this is an equality gate, not a
      tolerance);
    * **collection overhead < 5%** — instrumented async decode steps
      (metrics registry attached through runtime + link + offload) vs
      bare steps, min-of-5 runs so scheduler noise can't fail the gate.
    """
    rows: List[Row] = []
    import json as json_mod

    from repro.configs import get
    from repro.obs import MetricsRegistry, export_chrome_trace, \
        profile_report
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()

    # -- export an async 2-stack decode step and validate the structure
    off = DecodeOffload(cfg, channels=16, stacks=2, placement="balanced",
                        async_mode=True)
    off.step(1)
    off.step(1)
    rt = off.rt
    out = RESULTS.parent / "obs_profile.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    t0 = time.perf_counter()
    trace = export_chrome_trace(rt, str(out))
    export_us = (time.perf_counter() - t0) * 1e6
    json_mod.loads(json_mod.dumps(trace))          # valid, round-trips
    events = trace["traceEvents"]
    op_slices = [e for e in events
                 if e.get("ph") == "X" and e.get("cat") == "op"]
    tracks = {(e["pid"], e["tid"]) for e in op_slices}
    busy_channels = {ch for h in rt.timeline.ops for ch in h.spans}
    assert len(tracks) == len(busy_channels), (tracks, busy_channels)
    assert any(e.get("ph") == "M" and e.get("name") == "process_name"
               and e["args"]["name"] == "host-link" for e in events)
    s_ids = sorted(e["id"] for e in events if e.get("ph") == "s")
    f_ids = sorted(e["id"] for e in events if e.get("ph") == "f")
    assert s_ids and s_ids == f_ids, "unmatched dep flow pairs"
    rows.append((f"obs/chrome_export_{cfg.name}_2stack", export_us,
                 f"events={len(events)} tracks={len(tracks)} "
                 f"flows={len(s_ids)} artifact={out.name}"))

    # -- critical path: exact partition of the makespan
    t0 = time.perf_counter()
    rep = profile_report(rt)
    walk_us = (time.perf_counter() - t0) * 1e6
    mk = rep.makespan_cycles
    assert mk == rt.timeline.now, (mk, rt.timeline.now)
    cov = rep.coverage_cycles
    assert abs(cov - mk) <= 1e-9 * max(1.0, mk), (cov, mk)
    attributed = sum(rep.by_op.values())
    rows.append((f"obs/critical_path_{cfg.name}_2stack", walk_us,
                 f"makespan={mk:.0f} coverage={cov:.0f} "
                 f"attributed={attributed:.0f} slack={rep.slack_cycles:.0f} "
                 f"segments={len(rep.segments)} "
                 f"top={rep.top(1)[0][0] if rep.by_op else 'n/a'}"))

    # -- collection overhead: instrumented vs bare async decode steps
    def steps_wall(metrics):
        o = DecodeOffload(cfg, channels=16, placement="balanced",
                          async_mode=True, metrics=metrics)
        o.step(1)                      # warm caches / memoized splits
        t0 = time.perf_counter()
        for _ in range(10):
            o.step(1)
        return time.perf_counter() - t0

    steps_wall(None)                   # one throwaway: shared warmup
    # paired rounds, min of per-round ratios: background load slows both
    # sides of a round about equally, so the ratio stays a measurement
    # of the instrumentation itself rather than of machine noise
    rounds = [(steps_wall(None), steps_wall(MetricsRegistry()))
              for _ in range(5)]
    overhead = min(i / b for b, i in rounds)
    base = min(b for b, _ in rounds)
    inst = min(i for _, i in rounds)
    assert overhead <= 1.05, (overhead, rounds)
    rows.append((f"obs/metrics_overhead_{cfg.name}", inst / 10 * 1e6,
                 f"bare_s={base:.4f} instrumented_s={inst:.4f} "
                 f"overhead={overhead:.3f} gate<=1.05"))

    # -- serialized shadow profiler (profile=True), reported not gated:
    # barrier placement + per-op record vs an unprofiled twin
    def gemv_wall(profile):
        rt_s = PIMRuntime(channels=16, profile=profile)
        w = rt_s.place((2048, 2048), placement="balanced")
        t0 = time.perf_counter()
        for _ in range(50):
            rt_s.gemv(w, np.zeros(2048, np.float16),
                      placement="balanced", execute=False)
        return time.perf_counter() - t0

    gemv_wall(False)
    p_off = min(gemv_wall(False) for _ in range(5))
    p_on = min(gemv_wall(True) for _ in range(5))
    rows.append(("obs/shadow_profiler_gemv_16ch", p_on / 50 * 1e6,
                 f"bare_s={p_off:.4f} profiled_s={p_on:.4f} "
                 f"overhead={p_on / p_off:.3f}"))

    LAST_OBS_METRICS.update(
        obs_makespan_cycles=mk,
        obs_coverage_cycles=cov,
        obs_slack_cycles=rep.slack_cycles,
        obs_trace_events=float(len(events)),
        obs_tracks=float(len(tracks)),
        obs_flow_pairs=float(len(s_ids)),
        obs_overhead_ratio=overhead)
    return rows


def engine_bench() -> List[Row]:
    """Fast-path microbench: the PR-over-PR perf trajectory of the harness
    itself (not the modeled hardware).

    Gates are machine-independent — relative to the in-run reference path,
    never absolute seconds:

    * batched numeric GEMM must stay within 2x of the per-tile reference
      wall-clock (catching a >2x regression of the numeric fast path);
      measured ~2x *faster* on the 2-core dev host — the bit-exact
      per-ascending-k FP16 accumulator rounding makes the chain
      memory-bound, so the gap widens with cores, not with shape;
    * the numeric decode matmul set (the decode-on-PIM regime) must not
      regress vs per-tile; measured ~1.8x faster;
    * closed-form analytic must be >= 20x faster than the generator walk
      at the 16-channel paper-scale GEMM, with bit-identical ledgers
      (measured 2-3 orders of magnitude).

    All comparisons also assert bit-exact numerics / equal ledgers.
    """
    rows: List[Row] = []
    rng = np.random.default_rng(11)

    def timed(fn, reps=2):
        fn()                          # warm (jit compile)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        return (time.perf_counter() - t0) / reps, out

    # numeric GEMM 1024^3: batched whole-shard scan vs per-tile walk
    m = k = n = 1024
    a = (rng.standard_normal((m, k)) * 0.1).astype(np.float16)
    b = (rng.standard_normal((k, n)) * 0.1).astype(np.float16)
    t_tile, (y_t, rep_t) = timed(lambda: pim_gemm(a, b, engine="tiled"))
    t_bat, (y_b, rep_b) = timed(lambda: pim_gemm(a, b, engine="batched"))
    assert np.array_equal(np.asarray(y_t), np.asarray(y_b))
    assert rep_t.makespan_cycles == rep_b.makespan_cycles
    assert rep_t.total_commands == rep_b.total_commands
    # regression gate: fast path within 2x of the reference walk
    assert t_bat <= 2 * t_tile, (t_bat, t_tile)
    rows.append((f"engine/gemm_{m}x{k}x{n}_numeric", t_bat * 1e6,
                 f"tiled_s={t_tile:.3f} batched_s={t_bat:.3f} "
                 f"speedup={t_tile / t_bat:.2f} bit_exact=yes"))
    LAST_ENGINE_METRICS.update(gemm_tiled_s=t_tile, gemm_batched_s=t_bat,
                               gemm_speedup=t_tile / t_bat)

    # the numeric decode matmul set (serve-loop decode-on-PIM): many small
    # resident-weight GEMMs across 16 channels, where per-shard dispatch
    # overhead dominates the per-tile walk
    from repro.configs import get
    from repro.serve.offload import DecodeOffload

    cfg = get("qwen3-1.7b").reduced()

    def decode_step(mode):
        off = DecodeOffload(cfg, channels=16, placement="balanced",
                            numeric=True, engine=mode)
        off.step(4)                    # warm compiles
        best = float("inf")
        for _ in range(2):             # min-of-2: shield the CI gate from
            t0 = time.perf_counter()   # single-sample scheduler noise
            rec = off.step(4)
            best = min(best, time.perf_counter() - t0)
        return best, rec

    t_tile, rec_t = decode_step("tiled")
    t_bat, rec_b = decode_step("batched")
    assert rec_t.pim_cycles == rec_b.pim_cycles
    assert rec_b.logits_max_err < 0.05 and rec_t.logits_max_err < 0.05
    assert t_bat <= 1.5 * t_tile, (t_bat, t_tile)   # no-regression gate
    rows.append((f"engine/decode_matmul_set_{cfg.name}_numeric",
                 t_bat * 1e6,
                 f"tiled_s={t_tile:.3f} batched_s={t_bat:.3f} "
                 f"speedup={t_tile / t_bat:.2f} "
                 f"logits_err={rec_b.logits_max_err:.1e}"))
    LAST_ENGINE_METRICS.update(decode_tiled_s=t_tile, decode_batched_s=t_bat,
                               decode_speedup=t_tile / t_bat)

    # analytic 16-channel paper-scale GEMM: closed-form vs generator walk
    ma = ka = na = 4096
    aa = np.zeros((ma, ka), np.float16)
    ba = np.zeros((ka, na), np.float16)

    def run_analytic(mode):
        return pim_gemm(aa, ba, channels=16, placement="2d-block",
                        execute=False, engine=mode)[1]

    t_walk, rep_w = timed(lambda: run_analytic("tiled"))
    t_closed, rep_c = timed(lambda: run_analytic("batched"))
    for cw, cc in zip(rep_w.per_channel, rep_c.per_channel):
        assert (cw.compute_cycles, cw.flops, cw.commands) \
            == (cc.compute_cycles, cc.flops, cc.commands)
    assert t_closed * 20 <= t_walk, (t_closed, t_walk)
    rows.append((f"engine/analytic_gemm_{ma}^3_16ch", t_closed * 1e6,
                 f"walk_s={t_walk:.3f} closed_s={t_closed:.5f} "
                 f"speedup={t_walk / t_closed:.0f} ledgers=identical"))
    LAST_ENGINE_METRICS.update(analytic_walk_s=t_walk,
                               analytic_closed_s=t_closed,
                               analytic_speedup=t_walk / t_closed)
    return rows


def faults_sweep() -> List[Row]:
    """Fault injection + graceful degradation gates (CI ``bench-faults``).

    * **degradation curve** — killing 1 of 16 channels before a large
      row-striped GEMM must cost no more than the ideal work
      redistribution: ``degraded <= ideal * (16/15) * 1.05`` (the shape
      is chosen so 240 row blocks divide evenly both ways, making 16/15
      the exact redistribution factor);
    * **empty-plan overhead < 5%** — attaching ``FaultPlan()`` must not
      slow the runtime measurably (min-of-5 paired wall clocks), on top
      of the exact ledger/trace equality the test suite already proves;
    * **seed determinism** — two fresh runs of the same flaky-link
      scenario produce ``==``-equal host-link ledgers.
    """
    rows: List[Row] = []
    from repro.faults import FaultPlan, LinkTransient
    from repro.runtime.trace import emit_trace

    # -- degradation curve: 1 dead channel of 16 ------------------------
    # 30720 rows = 240 row blocks: 240/16 = 15 and 240/15 = 16 blocks
    # per channel, so ideal redistribution costs exactly 16/15
    m, k, n = 30720, 256, 256
    a = np.zeros((m, k), np.float16)
    b = np.zeros((k, n), np.float16)
    _, ideal = PIMRuntime(channels=16).gemm(a, b, placement="row-striped")
    rt_deg = PIMRuntime(channels=16, faults="kill channel 0 @ 0")
    _, deg = rt_deg.gemm(a, b, placement="row-striped")
    ratio = deg.cluster_makespan_cycles / ideal.cluster_makespan_cycles
    bound = (16 / 15) * 1.05
    assert ratio <= bound, (ratio, bound)
    assert deg.failed_channels == (0,)
    rows.append(("faults/degradation_1of16", 0.0,
                 f"ideal={ideal.cluster_makespan_cycles:.0f}cyc "
                 f"degraded={deg.cluster_makespan_cycles:.0f}cyc "
                 f"ratio={ratio:.4f} bound={bound:.4f}"))
    LAST_FAULTS_METRICS.update(degradation_ratio=ratio,
                               degradation_bound=bound)

    # -- empty-plan overhead: min-of-paired wall clocks -----------------
    def run_once(faults):
        rt = PIMRuntime(channels=8, stacks=2, faults=faults)
        h = rt.place((4096, 256), placement="row-striped", other_dim=1)
        x = np.zeros(256, np.float16)
        t0 = time.perf_counter()
        for _ in range(8):
            rt.gemv(h, x, placement="row-striped", execute=False)
        return time.perf_counter() - t0, rt

    bare_s = plan_s = float("inf")
    for _ in range(5):
        tb, rt_b = run_once(None)
        tp, rt_p = run_once(FaultPlan())
        bare_s, plan_s = min(bare_s, tb), min(plan_s, tp)
    overhead = plan_s / bare_s
    assert rt_b.stack.link == rt_p.stack.link
    assert emit_trace(rt_b.stack) == emit_trace(rt_p.stack)
    assert overhead < 1.05, overhead
    rows.append(("faults/empty_plan_overhead", plan_s * 1e6,
                 f"bare={bare_s * 1e6:.0f}us plan={plan_s * 1e6:.0f}us "
                 f"ratio={overhead:.3f} (gate < 1.05)"))
    LAST_FAULTS_METRICS.update(empty_plan_overhead=overhead)

    # -- seed determinism: same scenario, same ledgers ------------------
    def flaky_run():
        plan = FaultPlan(seed=11, link_transient=LinkTransient(prob=0.7))
        rt = PIMRuntime(channels=8, stacks=2, faults=plan)
        h = rt.place((4096, 256), placement="row-striped", other_dim=1)
        x = np.zeros(256, np.float16)
        for _ in range(4):
            rt.gemv(h, x, placement="row-striped", execute=False)
        return rt

    ra, rb = flaky_run(), flaky_run()
    deterministic = (ra.stack.link == rb.stack.link
                     and ra.faults.counters == rb.faults.counters)
    assert deterministic
    retries = int(ra.faults.counters.get("link_retries", 0))
    assert retries > 0, "p=0.7 transient produced no retransmits"
    rows.append(("faults/seed_determinism", 0.0,
                 f"retries={retries} "
                 f"link_cycles={ra.stack.link.cycles} identical=True"))
    LAST_FAULTS_METRICS.update(seed_deterministic=float(deterministic),
                               link_retries=float(retries))
    return rows


def kv_sweep() -> List[Row]:
    """KV-cache-resident attention decode gates (CI ``bench-kv``).

    * **paged vs streamed at 8k context** — one attention step (score
      GEMV + softmax epilogue + context GEMV) against an 8192-token
      resident paged KV must beat the same step with the K/V shipped
      across the host link every step (row-striped host arrays) by
      >= 4x; measured ~10x, the gap is pure link traffic;
    * **per-step h2d flat in context** — a full analytic decode step
      against a 640-token and a 1280-token context must charge exactly
      the same host->PIM bytes (new-token activations + q + the new
      token's K/V only; the resident prefix is never re-shipped);
    * **eviction determinism** — two fresh numeric runs under the same
      capacity budget produce ``==``-equal KV summaries, per-channel
      h2d ledgers, and per-step h2d.
    """
    rows: List[Row] = []
    from repro.configs import get
    from repro.runtime import KVCacheManager
    from repro.serve.offload import DecodeOffload

    # -- paged-resident vs streamed attention step at 8k context --------
    ctx, hd, group, nchan = 8192, 64, 4, 16
    rt = PIMRuntime(channels=nchan)
    kv = KVCacheManager(rt, n_layers=1, n_kv_heads=1, head_dim=hd,
                        channels_for_layer=lambda ell: range(nchan))
    kv.request("r")
    kv.append_tokens("r", 0, ctx)
    q = np.zeros((hd, group), np.float16)

    def paged_step() -> float:
        K, VT = kv.tensors("r", 0, 0)
        scores, r1 = rt.gemm(K, q, placement="paged", keep_output=True,
                             execute=False)
        _, r2 = rt.softmax(scores, placement="paged", execute=False)
        _, r3 = rt.gemm(VT, scores, placement="paged", execute=False)
        scores.evict()
        return (r1.makespan_cycles + r2.makespan_cycles
                + r3.makespan_cycles)

    rt_str = PIMRuntime(channels=nchan)
    k_host = np.zeros((ctx, hd), np.float16)
    vt_host = np.zeros((hd, ctx), np.float16)
    s_host = np.zeros((ctx, group), np.float16)

    def streamed_step() -> float:
        _, r1 = rt_str.gemm(k_host, q, placement="row-striped",
                            execute=False)
        _, r2 = rt_str.gemm(vt_host, s_host, placement="row-striped",
                            execute=False)
        return r1.makespan_cycles + r2.makespan_cycles

    paged_cyc = [paged_step() for _ in range(3)]
    streamed_cyc = [streamed_step() for _ in range(3)]
    assert len(set(paged_cyc)) == 1 and len(set(streamed_cyc)) == 1
    speedup = streamed_cyc[0] / paged_cyc[0]
    assert speedup >= 4.0, (streamed_cyc[0], paged_cyc[0], speedup)
    rows.append(("kv/paged_vs_streamed_8k", 0.0,
                 f"paged={paged_cyc[0]:.0f}cyc "
                 f"streamed={streamed_cyc[0]:.0f}cyc "
                 f"speedup={speedup:.2f}x (gate >= 4x)"))
    LAST_KV_METRICS.update(paged_step_cycles=paged_cyc[0],
                           streamed_step_cycles=streamed_cyc[0],
                           paged_speedup_8k=speedup)

    # -- steady per-step h2d independent of context length --------------
    cfg = get("qwen3-1.7b").reduced()

    def steady_h2d(prefill: int) -> int:
        off = DecodeOffload(cfg, channels=4, kv_offload=True)
        off.kv_prefill(0, prefill)
        recs = [off.step(1, request_ids=[0]) for _ in range(3)]
        steady = {r.h2d_bytes for r in recs[1:]}
        assert len(steady) == 1, steady
        return steady.pop()

    h2d_short, h2d_long = steady_h2d(640), steady_h2d(1280)
    assert h2d_short == h2d_long, (h2d_short, h2d_long)
    rows.append(("kv/steady_h2d_flat", 0.0,
                 f"ctx=640:{h2d_short}B ctx=1280:{h2d_long}B "
                 f"(gate ==; resident prefix never re-shipped)"))
    LAST_KV_METRICS.update(steady_step_h2d_bytes=float(h2d_short),
                           h2d_flat=float(h2d_short == h2d_long))

    # -- eviction determinism under a fixed seed ------------------------
    def evict_run():
        off = DecodeOffload(cfg, channels=4, numeric=True,
                            kv_offload=True, kv_capacity_bytes=200_000)
        for rid in ("a", "b"):
            off.kv_prefill(rid, 260)
        for _ in range(3):
            off.step(2, request_ids=["a", "b"])
        return (off.kv.summary(),
                [d.xfer.h2d_bytes for d in off.rt.stack],
                [s.h2d_bytes for s in off.steps])

    ea, eb = evict_run(), evict_run()
    deterministic = ea == eb
    assert deterministic, "paged eviction diverged across seeded runs"
    evictions = int(ea[0]["evictions"])
    assert evictions > 0, "200KB budget produced no evictions"
    rows.append(("kv/eviction_determinism", 0.0,
                 f"evictions={evictions} "
                 f"evict_bytes={ea[0]['evict_bytes']} "
                 f"restore_bytes={ea[0]['restore_bytes']} identical=True"))
    LAST_KV_METRICS.update(evict_deterministic=float(deterministic),
                           evictions=float(evictions))
    return rows


def serve_sweep() -> List[Row]:
    """Production-traffic serving gates (CI ``bench-serve``).

    * **SLO frontier, disaggregated vs colocated** — for each model
      config (qwen3-1.7b dense, mixtral-8x22b MoE) sweep six Poisson
      load points (0.25..1.0 x the analytic capacity) through
      :class:`repro.serve.loop.TrafficServer` in both phase layouts.
      The prompt length is auto-balanced so one request's prefill work
      roughly equals its decode work — the regime where disaggregation
      pays most and the colocated baseline is *not* a strawman (each
      phase alone would saturate the shared engine at the same rate).
      Gate: disaggregated goodput at the SLO knee (highest-load point
      with >= 0.9 attainment, else the max-goodput point) must be
      >= 1.3x the colocated knee goodput for *every* config;
    * **seed determinism** — two fresh servers over the same seeded
      trace produce ``==``-equal latency summaries (every percentile,
      byte count, and iteration count);
    * **zero-traffic additivity** — constructing a
      :class:`TrafficServer` around an offload and running an *empty*
      trace must leave the offload byte-identical to a bare one:
      ``==``-equal host-link ledgers, per-channel h2d ledgers, and
      per-step records, plus byte-identical ``emit_trace`` output.
    """
    rows: List[Row] = []
    from repro.configs import get
    from repro.runtime.trace import emit_trace
    from repro.serve.loop import TrafficServer
    from repro.serve.offload import DecodeOffload
    from repro.serve.traffic import SLO, HostCostModel, poisson_trace

    SLOTS, MAX_NEW, CHUNK, N_REQ, SEED = 8, 16, 2048, 250, 7
    MULTS = (0.25, 0.4, 0.55, 0.7, 0.85, 1.0)
    PCTS = ("p50", "p99")

    def knee(points: List[dict], label: str) -> dict:
        """Highest-goodput point with >= 0.9 attainment; if the mode
        never attains 0.9 (colocated under balanced load), fall back to
        its best-goodput point so the ratio compares peaks."""
        ok = [p for p in points if p[label]["slo_attainment"] >= 0.9]
        return max(ok or points, key=lambda p: p[label]["goodput_rps"])

    frontier: dict = {}
    ratios: dict = {}
    for name in ("qwen3-1.7b", "mixtral-8x22b"):
        cfg = get(name)
        off = DecodeOffload(cfg, channels=16)
        cost = HostCostModel(cfg)
        probe = off.step(SLOTS)
        step_costs = {SLOTS: (probe.pim_s, probe.h2d_bytes)}
        step_s = probe.pim_s
        # balance prefill vs decode work per request: prompt_len such
        # that prefill_s(prompt) ~= max_new * step_s / slots
        d_req = MAX_NEW * step_s / SLOTS
        per_tok = cost.flops_per_token / cost.peak_flops
        prompt = max(512, int(round(d_req / per_tok / 256)) * 256)
        p_req = cost.prefill_s(prompt)
        cap = 1.0 / max(p_req, d_req)       # disaggregated capacity
        # TPOT budget: batched decode hands each request one token per
        # full-batch step, so per-request TPOT ~= step_s (not /slots)
        slo = SLO(ttft_s=4 * p_req, tpot_s=1.3 * step_s)
        points: List[dict] = []
        for mult in MULTS:
            rate = mult * cap
            tr = poisson_trace(rate, N_REQ, seed=SEED,
                               prompt_len=prompt, max_new=MAX_NEW)
            pt = {"load": mult, "rate_rps": round(rate, 4)}
            for label, dis in (("disagg", True), ("colocated", False)):
                srv = TrafficServer(off, slots=SLOTS, disaggregate=dis,
                                    chunk_tokens=CHUNK, slo=slo,
                                    step_costs=step_costs)
                srv.run(tr)
                s = srv.latency_summary()
                pt[label] = {
                    "goodput_rps": round(s["goodput_rps"], 4),
                    "throughput_rps": round(s["throughput_rps"], 4),
                    "slo_attainment": round(s["slo_attainment"], 4),
                    **{f"ttft_{p}_s": round(s["ttft_s"][p], 4)
                       for p in PCTS},
                    **{f"tpot_{p}_s": round(s["tpot_s"][p], 4)
                       for p in PCTS},
                }
            points.append(pt)
        kd, kc = knee(points, "disagg"), knee(points, "colocated")
        gp_d = kd["disagg"]["goodput_rps"]
        gp_c = max(kc["colocated"]["goodput_rps"], 1e-12)
        ratios[name] = gp_d / gp_c
        frontier[name] = {
            "prompt_len": prompt,
            "max_new": MAX_NEW,
            "slots": SLOTS,
            "capacity_rps": round(cap, 4),
            "slo": {"ttft_s": round(slo.ttft_s, 4),
                    "tpot_s": round(slo.tpot_s, 4)},
            "points": points,
            "knee": {"disagg_load": kd["load"],
                     "colocated_load": kc["load"],
                     "disagg_goodput_rps": gp_d,
                     "colocated_goodput_rps":
                         kc["colocated"]["goodput_rps"],
                     "goodput_ratio": round(ratios[name], 4)},
        }
        rows.append((f"serve/frontier_{name}", 0.0,
                     f"knee disagg={gp_d:.3f}rps@x{kd['load']} "
                     f"colo={kc['colocated']['goodput_rps']:.3f}rps"
                     f"@x{kc['load']} ratio={ratios[name]:.2f}x "
                     f"(gate >= 1.3x, {len(MULTS)} load points)"))
    min_ratio = min(ratios.values())
    assert min_ratio >= 1.3, ratios

    # -- seed determinism: same trace, fresh servers, ==-equal summary --
    cfg = get("qwen3-1.7b")
    off = DecodeOffload(cfg, channels=16)
    q = frontier["qwen3-1.7b"]
    tr = poisson_trace(0.55 * q["capacity_rps"], N_REQ, seed=SEED,
                       prompt_len=q["prompt_len"], max_new=MAX_NEW)
    slo = SLO(**q["slo"])

    def one_run() -> dict:
        srv = TrafficServer(off, slots=SLOTS, disaggregate=True,
                            chunk_tokens=CHUNK, slo=slo)
        srv.run(tr)
        return srv.latency_summary()

    sa, sb = one_run(), one_run()
    deterministic = sa == sb
    assert deterministic, "seeded serving run diverged"
    rows.append(("serve/seed_determinism", 0.0,
                 f"two runs @0.55x load: ttft_p99={sa['ttft_s']['p99']:.3f}s "
                 f"goodput={sa['goodput_rps']:.3f}rps identical=True"))

    # -- bursty arrivals: cv~2 at the same offered load can only hurt ----
    # (burst clumps overflow the queue/SLO budget that Poisson clears;
    # equal mean rate, so any goodput gain would be a scheduler bug)
    from repro.serve.traffic import bursty_trace
    btr = bursty_trace(0.55 * q["capacity_rps"], N_REQ, cv=2.0, seed=SEED,
                       prompt_len=q["prompt_len"], max_new=MAX_NEW)
    bsrv = TrafficServer(off, slots=SLOTS, disaggregate=True,
                         chunk_tokens=CHUNK, slo=slo)
    bsrv.run(btr)
    bs = bsrv.latency_summary()
    assert bs["goodput_rps"] <= sa["goodput_rps"] + 1e-9, \
        (bs["goodput_rps"], sa["goodput_rps"])
    bursty = {
        "load": 0.55, "cv": 2.0,
        "goodput_rps": round(bs["goodput_rps"], 4),
        "poisson_goodput_rps": round(sa["goodput_rps"], 4),
        "slo_attainment": round(bs["slo_attainment"], 4),
        "ttft_p99_s": round(bs["ttft_s"]["p99"], 4),
    }
    rows.append(("serve/bursty_cv2", 0.0,
                 f"bursty cv=2 @0.55x: goodput={bs['goodput_rps']:.3f}rps "
                 f"<= poisson {sa['goodput_rps']:.3f}rps "
                 f"attainment={bs['slo_attainment']:.2f}"))

    # -- zero-traffic additivity: the layer off is byte-free -------------
    rcfg = get("qwen3-1.7b").reduced()

    def decode_run(wrap: bool):
        off = DecodeOffload(rcfg, channels=4, stacks=2)
        if wrap:
            srv = TrafficServer(off, slots=2)
            srv.run(poisson_trace(1.0, 0, seed=0))
        for _ in range(3):
            off.step(2)
        return (off.rt.stack.link,
                [d.xfer.h2d_bytes for d in off.rt.stack],
                [s.h2d_bytes for s in off.steps],
                emit_trace(off.rt.stack))

    bare, wrapped = decode_run(False), decode_run(True)
    additive = bare == wrapped
    assert additive, "idle traffic layer perturbed the offload"
    rows.append(("serve/zero_traffic_additivity", 0.0,
                 f"link==link h2d=={bare[1]} trace bytes identical "
                 f"with traffic layer off"))

    LAST_SERVE_METRICS.update(
        frontier=frontier,
        bursty=bursty,
        disagg_vs_colo_goodput=round(min_ratio, 4),
        frontier_points=float(len(MULTS)),
        seed_deterministic=float(deterministic),
        zero_traffic_additive=float(additive))
    return rows


def moe_sweep() -> List[Row]:
    """Routed-MoE expert-parallelism gates (CI ``bench-moe``).

    * **skew-driven placement + replication vs round-robin** — a
      Zipf(1.0) routing profile on mixtral-8x22b (8 experts, top-2)
      across 4 stacks: greedy mass-balanced placement with
      ``replicate_experts=4`` (mass-proportional copy counts) must beat
      round-robin homes by >= 1.3x decode makespan, with observed
      max/mean tokens-per-stack <= 1.15 (round-robin sits near 1.6
      under this skew — the win is pure load balance, the per-expert
      GEMV cost model is identical in both runs);
    * **replication sweep** — balance and replica hit-rate at
      ``replicate_experts`` in {0, 2, 4} for the skew table in
      ``docs/moe.md``;
    * **seed determinism** — two fresh routed offloads over the same
      profile produce ``==``-equal step records and tokens-per-stack;
    * **migration** — on a reduced config with ``link_topology=
      "switched"``, drifting the live traffic via ``set_routing`` fires
      at least one expert migration, charged as ``reupload`` on the
      destination stack's link and round-tripped through the trace as
      a ``# MIGRATE`` marker;
    * a deepseek-v3 ``reduced()`` row shows the placer handles a
      256->4-expert shared+dense-prefix config unchanged.
    """
    rows: List[Row] = []
    from repro.configs import get
    from repro.runtime.trace import emit_trace, parse_trace
    from repro.serve.offload import DecodeOffload
    from repro.serve.traffic import zipf_routing

    STACKS, BATCH, TOKENS, SEED, REP = 4, 32, 4096, 3, 4
    cfg = get("mixtral-8x22b")
    n_moe = cfg.n_layers - cfg.moe.first_dense_layers
    prof = zipf_routing(n_moe, cfg.moe.num_experts, TOKENS,
                        alpha=1.0, seed=SEED)

    # -- round-robin baseline vs skew-driven greedy + replication --------
    rr = DecodeOffload(cfg, stacks=STACKS, routing=prof,
                       replicate_experts=0,
                       expert_placement="roundrobin")
    rr_cycles = rr.step(BATCH).pim_cycles
    rr_ms = rr.moe_summary()
    # the makespan-driving figure is the WORST LAYER's max/mean (layer
    # costs serialize on their max stack); round-robin's aggregate
    # balance looks fine because per-layer hot experts permute across
    # layers and average out — don't be fooled by it
    rr_worst = rr_ms["placement_worst_layer_max_over_mean"]

    sweep: dict = {}
    best_rec = None
    for rep in (0, 2, REP):
        off = DecodeOffload(cfg, stacks=STACKS, routing=prof,
                            replicate_experts=rep)
        rec = off.step(BATCH)
        ms = off.moe_summary()
        sweep[rep] = {
            "speedup_vs_roundrobin": round(rr_cycles / rec.pim_cycles, 4),
            "balance_max_over_mean":
                round(ms["observed_max_over_mean"], 4),
            "worst_layer_balance":
                round(ms["placement_worst_layer_max_over_mean"], 4),
            "replica_hit_rate": round(ms["replica_hit_rate"], 4),
        }
        if rep == REP:
            best_rec, best_off = rec, off
        rows.append((f"moe/greedy_rep{rep}", 0.0,
                     f"speedup={sweep[rep]['speedup_vs_roundrobin']:.3f}x "
                     f"balance={sweep[rep]['balance_max_over_mean']:.3f} "
                     f"worst_layer={sweep[rep]['worst_layer_balance']:.3f} "
                     f"hit_rate={sweep[rep]['replica_hit_rate']:.3f} "
                     f"(rr worst_layer={rr_worst:.3f})"))
    speedup = sweep[REP]["speedup_vs_roundrobin"]
    balance = sweep[REP]["balance_max_over_mean"]
    assert speedup >= 1.3, sweep
    assert balance <= 1.15, sweep

    # -- seed determinism: fresh routed offload, ==-equal outcome --------
    off2 = DecodeOffload(cfg, stacks=STACKS, routing=prof,
                         replicate_experts=REP)
    rec2 = off2.step(BATCH)
    deterministic = (best_rec == rec2
                     and best_off.tokens_per_stack == off2.tokens_per_stack
                     and best_off.moe_counters == off2.moe_counters)
    assert deterministic, "seeded routed-MoE run diverged"
    rows.append(("moe/seed_determinism", 0.0,
                 f"two runs: tokens_per_stack={off2.tokens_per_stack} "
                 f"identical=True"))

    # -- migration under drift (reduced config, switched topology) -------
    rcfg = get("mixtral-8x22b").reduced()
    rn_moe = rcfg.n_layers - rcfg.moe.first_dense_layers
    rprof = zipf_routing(rn_moe, rcfg.moe.num_experts, 512,
                         alpha=1.0, seed=SEED)
    drift = zipf_routing(rn_moe, rcfg.moe.num_experts, 512,
                         alpha=1.0, seed=SEED + 40)
    mig = DecodeOffload(rcfg, channels=4, stacks=2, routing=rprof,
                        replicate_experts=1, migrate_threshold=0.05,
                        migrate_min_tokens=16, link_topology="switched")
    mig.step(4)
    mig.set_routing(drift)
    for _ in range(4):
        mig.step(4)
    migrations = mig.moe_counters["migrations"]
    st = parse_trace(emit_trace(mig.rt.stack))
    reup = sum(n for led in mig.rt.stack.all_links()
               for k, n in led.events if k == "reupload")
    assert migrations >= 1 and st.migrate_events and reup > 0, \
        (migrations, len(st.migrate_events), reup)
    rows.append(("moe/migration_drift", 0.0,
                 f"{migrations} migrations, "
                 f"{len(st.migrate_events)} MIGRATE markers, "
                 f"reupload_bytes={reup} on per-stack links"))

    # -- deepseek-v3 reduced: shared experts + dense prefix --------------
    dcfg = get("deepseek-v3-671b").reduced()
    dn_moe = dcfg.n_layers - dcfg.moe.first_dense_layers
    dprof = zipf_routing(dn_moe, dcfg.moe.num_experts, 512,
                         alpha=1.0, seed=SEED)
    doff = DecodeOffload(dcfg, channels=4, stacks=2, routing=dprof,
                         replicate_experts=1)
    doff.step(4)
    dms = doff.moe_summary()
    rows.append(("moe/deepseek_reduced", 0.0,
                 f"balance={dms['observed_max_over_mean']:.3f} "
                 f"hit_rate={dms['replica_hit_rate']:.3f} "
                 f"(shared experts + dense prefix route correctly)"))

    LAST_MOE_METRICS.update(
        speedup_vs_roundrobin=speedup,
        balance_max_over_mean=balance,
        worst_layer_balance=sweep[REP]["worst_layer_balance"],
        roundrobin_worst_layer_balance=round(rr_worst, 4),
        replica_hit_rate=sweep[REP]["replica_hit_rate"],
        replication_sweep={str(k): v for k, v in sweep.items()},
        migrations=float(migrations),
        seed_deterministic=float(deterministic))
    return rows


ALL = {
    "fig7": fig7_pep_cycles,
    "fig8": fig8_ame_instructions,
    "fig9": fig9_tile_scaling,
    "table3": table3_comparison,
    "channels": channel_sweep,
    "residency": residency_sweep,
    "engine": engine_bench,
    "cluster": cluster_sweep,
    "decode": decode_async_sweep,
    "obs": obs_sweep,
    "faults": faults_sweep,
    "kv": kv_sweep,
    "serve": serve_sweep,
    "moe": moe_sweep,
}
